"""Cost-model calibration: measured batch_meta cells -> fitted surface ->
calibrated admission, end to end on a live paged engine.

Drives a paged ServeEngine (CPU backend, reduced model) across a grid of
occupancies and prompt lengths so traffic lands in several (rows, width)
decode cells and (rows, bucket) prefill cells, then:

  1. ingests the pool-wide per-cell timing aggregates into a
     ``StepCostModel`` and fits the per-phase roofline surface;
  2. scores the surface against the measured means per cell
     (predicted-vs-measured relative error — the interpolation quality the
     calibrated admission bound leans on);
  3. runs the admission capacity experiment: identical streams declared at
     the conservative full-width worst case (2x the costliest measured
     cell — what a profiler would declare) are admitted one by one until
     the Eqs (1)-(6) check rejects; calibrated admission re-prices each
     stream at the bucket its traffic actually hits and must admit
     STRICTLY more streams.

Writes BENCH_cost_model.json (tracked artifact).  Exits nonzero when the
median relative error exceeds a generous threshold (the surface is a
2-feature linear fit over noisy CPU timings; 1.0 catches only a broken
fit, not an imprecise one) or when calibrated admission fails to beat the
worst-case declaration.  ``--smoke`` shrinks repeats for CI.
"""

from __future__ import annotations

import json
import sys
import threading
from pathlib import Path

import numpy as np

MEDIAN_REL_ERR_MAX = 1.0
MAX_STREAMS = 64


def _spec(name: str, steps: int):
    from repro.serving.engine import StreamSpec

    return StreamSpec(name=name, priority=1, period_ms=60_000.0,
                      deadline_ms=60_000.0, prefill_ms=100.0, decode_ms=50.0,
                      decode_steps=steps)


def _drive(engine, num_streams: int, *, steps: int, prompt_len: int) -> None:
    prompt = np.arange(1, prompt_len + 1, dtype=np.int32)[None, :] % 100
    names = [f"s{i}" for i in range(num_streams)]
    for n in names:
        decision = engine.admit(_spec(n, steps))
        assert decision.admitted, (n, decision.reason)
    threads = [threading.Thread(
        target=lambda n=n: engine.generate(n, prompt, steps=steps))
        for n in names]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for n in names:
        engine.remove(n)


def _admission_capacity(ctl, *, declared_ms: float, eta: int, period_ms: float,
                        cell=None) -> int:
    """Admit identical streams until the analysis rejects one."""
    from repro.core.task_model import GpuSegment, Task

    seg = GpuSegment(e=declared_ms * 0.9, m=declared_ms * 0.1)
    for i in range(MAX_STREAMS):
        task = Task(name=f"cap{i}", C=0.1, T=period_ms, D=period_ms,
                    segments=(seg,) * eta, priority=1)
        if not ctl.try_admit(task, cell=cell).admitted:
            return i
    return MAX_STREAMS


def main(*, smoke: bool = False) -> dict:
    import jax

    from repro.analysis.cost_model import StepCostModel, TrafficModel
    from repro.configs.registry import get_config
    from repro.core.admission import AdmissionController
    from repro.models import model as M
    from repro.serving.engine import ServeEngine

    cfg = get_config("internlm2_1_8b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    max_batch, max_seq, block = 4, 64, 16  # widths {1,2,4}, rows {1,2,4}
    engine = ServeEngine(cfg, params, max_seq=max_seq, ordering="fifo",
                         num_servers=1, batching=True, max_batch=max_batch,
                         paged=True, kv_block_size=block)
    steps = 12  # long prompts cross a block boundary mid-generation
    repeats = 1 if smoke else 3
    try:
        rep = engine.precompile(prompt_buckets=(4, 32))
        print(f"precompile: {rep.compiled} traces, {rep.skipped} skipped")
        # occupancy x prompt-length grid: low/full rows, narrow/wide gathers
        for _ in range(repeats):
            for streams, plen in ((1, 4), (2, 4), (4, 4), (1, 24), (4, 24)):
                _drive(engine, streams, steps=steps, prompt_len=plen)
        cell_stats = engine.pool.cell_stats()
        traffic = TrafficModel.from_stats(cell_stats)
    finally:
        engine.close()

    model = StepCostModel()
    n_cells = model.ingest(cell_stats)
    coeffs = model.fit()
    err = model.error_report()
    print(f"{n_cells} measured cells, median rel err "
          f"{err['median_rel_err']:.3f}, dispatch overhead "
          f"{model.dispatch_overhead_s() * 1e3:.3f} ms")

    # -- calibrated admission capacity vs worst-case declaration ----------
    decode_cells = [k for k in cell_stats if k[0] == "decode"]
    small = min(decode_cells, key=lambda k: k[1] * k[2])
    worst = max(decode_cells, key=lambda k: k[1] * k[2])
    declared_ms = 2.0 * model.predict(*worst) * 1e3  # profiler's margin
    calibrated_ms = model.safety * model.predict(*small) * 1e3
    eta = 4
    period_ms = max(20.0, 8 * eta * calibrated_ms)
    declared_n = _admission_capacity(
        AdmissionController(2, epsilon_ms=0.05),
        declared_ms=declared_ms, eta=eta, period_ms=period_ms)
    calibrated_n = _admission_capacity(
        AdmissionController(2, epsilon_ms=0.05, cost_model=model),
        declared_ms=declared_ms, eta=eta, period_ms=period_ms, cell=small)
    print(f"admission capacity: declared {declared_n} streams -> "
          f"calibrated {calibrated_n} streams "
          f"(declared {declared_ms:.2f} ms/step, calibrated "
          f"{calibrated_ms:.2f} ms/step in cell {small})")

    report = {
        "model": cfg.name,
        "max_batch": max_batch, "max_seq": max_seq, "block_size": block,
        "n_cells": n_cells,
        "median_rel_err": err["median_rel_err"],
        "median_rel_err_max": MEDIAN_REL_ERR_MAX,
        "cells": err["cells"],
        "coeffs": coeffs,
        "dispatch_overhead_ms": model.dispatch_overhead_s() * 1e3,
        "hot_cells": sorted(map(list, traffic.hot_cells(min_share=0.1))),
        "admission": {
            "eta": eta, "period_ms": period_ms,
            "declared_ms_per_step": declared_ms,
            "calibrated_ms_per_step": calibrated_ms,
            "calibrated_cell": list(small),
            "declared_streams": declared_n,
            "calibrated_streams": calibrated_n,
        },
    }
    # the smoke grid must not clobber the committed full-grid artifact
    name = "BENCH_cost_model_smoke.json" if smoke else "BENCH_cost_model.json"
    out = Path(__file__).parent / name
    out.write_text(json.dumps(report, indent=2))
    print(f"wrote {out}")

    failures = []
    if not err["median_rel_err"] <= MEDIAN_REL_ERR_MAX:
        failures.append(f"median rel err {err['median_rel_err']:.3f} > "
                        f"{MEDIAN_REL_ERR_MAX}")
    if not calibrated_n > declared_n:
        failures.append(f"calibrated admission ({calibrated_n}) did not beat "
                        f"worst-case declaration ({declared_n})")
    for f in failures:
        print(f"FAIL: {f}", file=sys.stderr)
    if failures:
        raise SystemExit(1)
    return report


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
