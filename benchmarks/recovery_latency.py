"""Recovery latency + degraded-mode throughput under mid-traffic failure.

Kills one server of an N-server pool while every stream is decoding (a
deterministic ``FaultInjector`` schedule) and measures the fault-tolerance
story end to end:

  * detection -> resume latency: from the injected device death
    (``FaultInjector.events`` timestamp) to the first token a recovered
    stream appends after re-prefilling its retained prefix on a survivor;
  * degraded throughput: decode tokens/s of the same workload on the full
    pool vs the post-failure pool, swept over pool size — the price of
    losing a device, with degraded-mode admission re-placing (never
    silently overloading) the displaced streams;
  * correctness alongside: every recovered stream's tokens must equal the
    failure-free run's (the chaos suite asserts this per scenario; here it
    guards the numbers being reported).

Writes BENCH_recovery.json next to this file.  ``--smoke`` shrinks the
sweep for CI.
"""

from __future__ import annotations

import json
import sys
import threading
import time
from pathlib import Path

import numpy as np

STEPS = 24
PROMPT_LEN = 4


def _spec(name: str, prio: int, steps: int = STEPS):
    from repro.serving.engine import StreamSpec

    return StreamSpec(name=name, priority=prio, period_ms=30_000.0,
                      deadline_ms=30_000.0, prefill_ms=50.0, decode_ms=5.0,
                      decode_steps=steps)


def _make_engine(cfg, params, *, num_servers: int, max_batch: int = 4):
    from repro.serving.engine import ServeEngine

    eng = ServeEngine(cfg, params, max_seq=64, ordering="fifo",
                      num_servers=num_servers, batching=True,
                      max_batch=max_batch, paged=True, kv_block_size=16)
    eng.enable_fault_tolerance(heartbeat_timeout_s=30.0)
    return eng


def _run(eng, names, prompt, *, steps: int = STEPS):
    results: dict[str, object] = {}

    def worker(n):
        try:
            results[n] = eng.generate(n, prompt, steps=steps)
        except Exception as e:  # noqa: BLE001 - shed streams are reported
            results[n] = e

    threads = [threading.Thread(target=worker, args=(n,)) for n in names]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return results, time.perf_counter() - t0


def _throughput(results, wall: float) -> float:
    tokens = sum(len(r.tokens) for r in results.values()
                 if not isinstance(r, Exception))
    return tokens / wall if wall > 0 else 0.0


def bench_pool(cfg, params, num_servers: int, *, streams_per_server: int,
               steps: int) -> dict:
    from repro.runtime.faultinject import FaultInjector, ServerFault

    prompt = np.arange(1, PROMPT_LEN + 1, dtype=np.int32)[None, :] % 100
    num_streams = num_servers * streams_per_server
    names = [f"s{i}" for i in range(num_streams)]

    # failure-free reference: tokens (correctness guard) + throughput
    eng = _make_engine(cfg, params, num_servers=num_servers)
    for i, n in enumerate(names):
        assert eng.admit(_spec(n, num_streams - i, steps)).admitted
    baseline, wall = _run(eng, names, prompt, steps=steps)
    want = {n: baseline[n].tokens for n in names}
    healthy_tps = _throughput(baseline, wall)
    eng.close()

    # faulted run: same workload, one server dies mid-decode
    eng = _make_engine(cfg, params, num_servers=num_servers)
    for i, n in enumerate(names):
        assert eng.admit(_spec(n, num_streams - i, steps)).admitted
    victim = eng.pool.server_of(names[0])
    # land the death well inside the decode phase of the victim's streams
    at_call = 2 * streams_per_server + 3
    inj = FaultInjector([ServerFault(server=victim, at_call=at_call,
                                     kind="die")])
    eng.pool.attach_fault_injector(inj)
    faulted, wall = _run(eng, names, prompt, steps=steps)
    degraded_tps = _throughput(faulted, wall)

    recovered = [n for n in names
                 if not isinstance(faulted[n], Exception)
                 and faulted[n].recoveries > 0]
    mismatches = [n for n in names
                  if not isinstance(faulted[n], Exception)
                  and faulted[n].tokens != want[n]]
    assert not mismatches, f"recovered tokens diverged: {mismatches}"
    assert recovered, "fault did not hit any decoding stream"

    # detection -> resume latency: injected-death timestamp (the server
    # thread raises DeviceLostError at that instant, so detection is
    # immediate for the die kind) to each recovered stream's resume point —
    # the retained prefix re-established on a survivor, ready to decode
    death_t = inj.events[0].at_monotonic
    resume_ms = [1e3 * (faulted[n].resumed_at_monotonic[0] - death_t)
                 for n in recovered]

    shed = [n for n in names if isinstance(faulted[n], Exception)]
    eng.close()
    return {
        "num_servers": num_servers,
        "num_streams": num_streams,
        "steps": steps,
        "victim": victim,
        "recovered_streams": len(recovered),
        "shed_streams": len(shed),
        "healthy_tokens_per_s": round(healthy_tps, 2),
        "degraded_tokens_per_s": round(degraded_tps, 2),
        "degraded_fraction": round(degraded_tps / healthy_tps, 4)
        if healthy_tps else None,
        "detect_to_resume_ms": {
            "mean": round(float(np.mean(resume_ms)), 3),
            "max": round(float(np.max(resume_ms)), 3),
        },
        "death_at_monotonic": death_t,
    }


def main() -> None:
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
    smoke = "--smoke" in sys.argv

    import jax

    from repro.configs.registry import get_config
    from repro.models import model as M

    cfg = get_config("internlm2_1_8b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    pool_sizes = (2,) if smoke else (2, 3, 4)
    steps = 12 if smoke else STEPS
    rows = [bench_pool(cfg, params, n, streams_per_server=2, steps=steps)
            for n in pool_sizes]

    out = {
        "config": "internlm2_1_8b.reduced",
        "mode": "smoke" if smoke else "full",
        "pools": rows,
    }
    path = Path(__file__).resolve().parent / "BENCH_recovery.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(json.dumps(out, indent=2))
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
