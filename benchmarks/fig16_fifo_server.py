"""Beyond-paper experiment: the FIFO-ordered GPU server the paper proposes
as future work ("we leave the extension of the GPU server with FIFO
ordering as part of future work", §6.3/Fig 15 discussion).

Question: does a FIFO server close the gap to FMLP+ in the homogeneous-
period regime where FMLP+ beats the priority server (Fig 15), while
keeping the server's no-busy-wait advantage?

Sweeps T_min with T_max = 500 ms, comparing: priority server (paper),
FIFO server (this extension, analyzed with the FIFO double bound),
FMLP+ (sync baseline).
"""

from __future__ import annotations

import random

from repro.core import fmlp_analysis, server_analysis
from repro.core.allocation import allocate
from repro.core.taskset_gen import GenParams, generate_taskset

from .sched_common import num_tasksets


def run(full: bool = False) -> list[str]:
    n_sets = num_tasksets(full)
    rows = [f"# fig16_fifo_server (beyond paper): % schedulable, {n_sets}/pt"]
    rows.append("fig16_fifo_server,N_P,tmin_ms,server_prio,server_fifo,fmlp")
    for np_ in (4, 8):
        for tmin in (20, 40, 80, 160, 320):
            rng = random.Random(hash(("fig16", np_, tmin)) & 0xFFFF)
            params = GenParams(num_cores=np_, period_ms=(tmin, 500.0))
            wins = {"prio": 0, "fifo": 0, "fmlp": 0}
            for _ in range(n_sets):
                tasks = generate_taskset(params, rng)
                server_sys = allocate(tasks, np_, approach="server",
                                      epsilon=params.epsilon_ms)
                wins["prio"] += server_analysis.analyze(server_sys).schedulable
                wins["fifo"] += server_analysis.analyze_fifo_server(
                    server_sys).schedulable
                sync_sys = allocate(tasks, np_, approach="sync")
                wins["fmlp"] += fmlp_analysis.analyze(sync_sys).schedulable
            rows.append(
                f"fig16_fifo_server,{np_},{tmin},"
                f"{100.0 * wins['prio'] / n_sets:.1f},"
                f"{100.0 * wins['fifo'] / n_sets:.1f},"
                f"{100.0 * wins['fmlp'] / n_sets:.1f}")
    return rows
