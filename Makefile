# Test entry points.  `make test` is the tier-1 verify command from
# ROADMAP.md; `make test-fast` is the same sweep with the @slow end-to-end
# tests deselected (the quick pre-commit loop).  `make bench-smoke` is the
# CI-sized paged-vs-masked-dense decode sweep; it writes
# BENCH_paged_decode_smoke.json (the committed full-grid artifact is
# BENCH_paged_decode.json from `--paged-sweep` without --smoke).

PYTEST = PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -x -q
PYRUN = PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python

.PHONY: test test-fast bench-smoke

test:
	$(PYTEST)

test-fast:
	$(PYTEST) -m "not slow"

bench-smoke:
	$(PYRUN) benchmarks/batching_throughput.py --paged-sweep --smoke
