# Test entry points.  `make test` is the tier-1 verify command from
# ROADMAP.md; `make test-fast` is the same sweep with the @slow end-to-end
# tests deselected (the quick pre-commit loop).

PYTEST = PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -x -q

.PHONY: test test-fast

test:
	$(PYTEST)

test-fast:
	$(PYTEST) -m "not slow"
