# Test entry points.  `make test` is the tier-1 verify command from
# ROADMAP.md; `make test-fast` is the same sweep with the @slow end-to-end
# tests deselected (the quick pre-commit loop).  `make bench-smoke` is the
# CI-sized benchmark pass: the paged-vs-masked-dense decode sweep (writes
# BENCH_paged_decode_smoke.json; the committed full-grid artifact is
# BENCH_paged_decode.json from `--paged-sweep` without --smoke; the same
# flag also emits one paged-vs-dense cell per cache family to
# BENCH_paged_families.json) plus the cost-model calibration loop.  `make bench-calibrate` runs the
# calibration alone: measure cells -> fit surface -> calibrated-admission
# capacity; writes BENCH_cost_model.json (tracked) and FAILS when the
# median predicted-vs-measured relative error blows past its threshold or
# calibrated admission stops beating the worst-case declaration.
# `make test-scenarios` runs the scenario-engine property pass (bound >=
# simulated WCRT on every CI matrix cell, bit-identical seeded replay,
# golden replay against the legacy simulator paths).

PYTEST = PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -x -q
PYRUN = PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python

.PHONY: test test-fast test-chaos test-migration test-scenarios \
	test-paged-families bench-smoke bench-calibrate

test:
	$(PYTEST)

test-fast:
	$(PYTEST) -m "not slow"

# deterministic fault-injection matrix (kill mid-decode / during prefill,
# double failure, transient storm, stall, degraded-mode shedding): asserts
# bit-identical recovered tokens and zero leaked blocks/slots
test-chaos:
	$(PYTEST) tests/test_chaos.py

# live KV-block migration: manager corners, work stealing, consolidation,
# elastic scale-up/down — bit-identical tokens and zero leaks throughout
test-migration:
	$(PYTEST) tests/test_migration.py

# registry-driven scenario matrix: every arrival model x protocol cell the
# analysis claims to cover, property-tested bound >= simulated WCRT
test-scenarios:
	$(PYTEST) tests/test_scenarios.py

# one paged substrate, every cache family (GQA / MLA latent / SSM slabs /
# hybrid / enc-dec shared segments): per-family greedy bit-identical to the
# unbatched dense path, migration round-trips, zero leaked
# blocks/slabs/segments
test-paged-families:
	$(PYTEST) tests/test_paged_families.py tests/test_models_paged.py \
		tests/test_kvcache.py

bench-smoke:
	$(PYRUN) benchmarks/batching_throughput.py --paged-sweep --smoke
	$(PYRUN) benchmarks/cost_model_calibrate.py --smoke
	$(PYRUN) benchmarks/recovery_latency.py --smoke
	$(PYRUN) benchmarks/scenario_matrix.py --smoke
	$(PYRUN) benchmarks/migration.py --smoke

bench-calibrate:
	$(PYRUN) benchmarks/cost_model_calibrate.py
